"""Paper Figs. 18/19: model accuracy under extreme churn — 50 new
clients join a 50-client FedLay mid-training; the new nodes' accuracy
catches up via high-confidence models from existing nodes.

Both phases run through the live control plane: the overlay before and
after the mass join is whatever :class:`repro.overlay.OverlayController`
converged to (no hand-rolled topology splice), and each joiner is
warm-started from its highest-confidence surviving neighbor under the
post-churn schedule (:func:`repro.overlay.joiner_donors` — the paper's
catch-up mechanism) instead of from scratch.
"""

from __future__ import annotations

import numpy as np

from repro.core.dfl import Engine, MethodSpec, capacity_periods, make_profiles
from repro.core.ndmp import Simulator
from repro.core.topology import Topology
from repro.overlay import ChurnTrace, OverlayController, joiner_donors

from .common import emit, mnist_task


def run(quick: bool = False) -> None:
    n_old = 8 if quick else 16
    n_total = 2 * n_old
    t_join = 10.0
    total = 30.0 if quick else 60.0
    task = mnist_task(n_clients=n_total, shards=3)
    periods = capacity_periods(n_total, 1.0, seed=0)
    profiles = make_profiles(task, periods)

    sim = Simulator(num_spaces=3, latency=0.05, heartbeat_period=0.5,
                    probe_period=1.0, seed=0)
    sim.seed_network(list(range(n_old)))
    ctl = OverlayController(
        sim, profiles_fn=lambda alive: {u: profiles[u] for u in alive})

    # phase 1: only the joined half trains — the not-yet-joined clients
    # are edgeless and dormant (period beyond the horizon)
    engine = Engine()
    topo_p1 = Topology(nodes=tuple(range(n_total)),
                       edges=ctl.topology().edges)
    periods_p1 = np.concatenate([periods[:n_old],
                                 np.full(n_old, 10 * t_join)])
    res1 = engine.run(task, MethodSpec(name="phase1", topology=topo_p1),
                      total_time=t_join, model_bytes=4096, seed=0,
                      periods=periods_p1)

    # mass join through NDMP; the controller swaps in the new schedule
    trace = ChurnTrace.scripted(
        [(ctl.sim.now + 0.1, "join", j, int(j % n_old))
         for j in range(n_old, n_total)])
    for _ in range(40):
        r = ctl.step(1.0, trace=trace)
        if len(r.alive) == n_total and ctl.sim.correctness() == 1.0:
            break
    emit("fig18_swap", n_old=n_old, n_total=n_total, epoch=ctl.epoch,
         swaps=ctl.swaps, correctness=round(ctl.sim.correctness(), 4))

    # phase 2: full network under the controller's post-churn overlay;
    # joiners warm-start from their highest-confidence old neighbor
    survivors = tuple(range(n_old))
    joiners = tuple(range(n_old, n_total))
    donors = joiner_donors(ctl.schedule, ctl.alive, joiners, survivors)
    init = list(res1.final_params[:n_old])
    for j in joiners:
        donor = donors.get(j)
        init.append(res1.final_params[donor].copy() if donor is not None
                    else task.init_params(0))
    topo_new = Topology(nodes=tuple(range(n_total)),
                        edges=ctl.topology().edges)
    res2 = engine.run(task, MethodSpec(name="phase2", topology=topo_new),
                      total_time=total - t_join, model_bytes=4096, seed=1,
                      periods=periods, init_params=init)
    for row in res2.trace:
        accs = row.accs
        if accs is None:
            continue
        emit("fig18", t=round(t_join + row.time, 1),
             old_nodes_acc=round(float(np.mean(accs[:n_old])), 4),
             new_nodes_acc=round(float(np.mean(accs[n_old:])), 4))


if __name__ == "__main__":
    run()
