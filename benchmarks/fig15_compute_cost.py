"""Paper Fig. 15: relative computation cost to reach a target accuracy
(FedAvg normalized to 1)."""

from __future__ import annotations

import numpy as np

from repro.core.dfl import Engine

from .common import emit, mnist_task


def _steps_to_reach(res, target: float):
    for row in res.trace:
        if row.mean_acc >= target:
            return max(row.time, 1e-9)
    return None


def run(quick: bool = False) -> None:
    total = 30.0 if quick else 60.0
    task = mnist_task()
    engine = Engine()
    results = {m: engine.run(task, m, total_time=total, model_bytes=4096,
                             seed=0)
               for m in ("fedavg", "fedlay", "gaia", "chord", "dfl-dds")}
    # target: 95% of FedAvg's final accuracy
    target = 0.95 * results["fedavg"].final_mean_acc
    base = _steps_to_reach(results["fedavg"], target)
    for m, res in results.items():
        t = _steps_to_reach(res, target)
        cost = None if (t is None or base is None) else round(t / base, 3)
        emit("fig15", method=m, target_acc=round(target, 4),
             time_to_target=round(t, 1) if t else "not_reached",
             relative_cost=cost if cost else "inf")


if __name__ == "__main__":
    run()
