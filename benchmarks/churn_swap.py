"""Beyond-paper microbenchmark: churn-triggered mixer hot-swap cost.

Measures what the live control plane (:mod:`repro.overlay`) adds to a
training step: host-side schedule rebuild latency, first-touch XLA
compile latency of a swapped-in mixer, steady-state (cached) mixer call
latency, and the compile-cache hit rate over a fail→rejoin cycle — the
rejoin restores the previous alive set, whose schedule hashes equal, so
the swap back is a pure cache hit with zero retrace.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.ndmp import Simulator
from repro.overlay import ChurnTrace, OverlayController

from .common import emit


def _converge(ctl: OverlayController, trace=None, steps=30):
    """Step until the overlay is correct; returns the report of the last
    step that actually swapped the mixer (or the final step if none)."""
    last = swap = None
    for _ in range(steps):
        last = ctl.step(1.0, trace=trace)
        trace = None
        if last.swapped:
            swap = last
        if ctl.sim.correctness() == 1.0:
            break
    return swap or last


def _timed_mix(ctl: OverlayController, X) -> float:
    t0 = time.perf_counter()
    out = ctl.mixer(X)
    out.block_until_ready()
    return (time.perf_counter() - t0) * 1e3


def run(quick: bool = False) -> None:
    n = 16 if quick else 64
    dim = 1024 if quick else 65536
    sim = Simulator(num_spaces=3, latency=0.05, heartbeat_period=0.5,
                    probe_period=1.0, seed=0)
    sim.seed_network(list(range(n)))
    ctl = OverlayController(sim)
    rng = np.random.default_rng(0)

    def stacked(m):
        return jnp.asarray(rng.normal(size=(m, dim)).astype(np.float32))

    # steady state: first call compiles, second runs the cached program
    r0 = _converge(ctl)
    cold = _timed_mix(ctl, stacked(len(ctl.alive)))
    warm = _timed_mix(ctl, stacked(len(ctl.alive)))
    emit("churn_swap", phase="steady", n=len(ctl.alive),
         rebuild_ms=round(r0.rebuild_ms, 3), compile_ms=round(cold, 1),
         exec_ms=round(warm, 2), cache_hit=int(r0.cache_hit))

    # fail one node: schedule changes -> rebuild + fresh compile
    victim = ctl.alive[n // 2]
    trace = ChurnTrace.scripted([(ctl.sim.now + 0.1, "fail", victim)])
    r1 = _converge(ctl, trace=trace)
    cold = _timed_mix(ctl, stacked(len(ctl.alive)))
    warm = _timed_mix(ctl, stacked(len(ctl.alive)))
    emit("churn_swap", phase="fail", n=len(ctl.alive),
         rebuild_ms=round(r1.rebuild_ms, 3), compile_ms=round(cold, 1),
         exec_ms=round(warm, 2), cache_hit=int(r1.cache_hit))

    # rejoin the same node: the alive set (and thus the schedule digest)
    # reverts -> the old compiled mixer comes straight from the cache
    trace = ChurnTrace.scripted([(ctl.sim.now + 0.1, "join", victim,
                                  int(ctl.alive[0]))])
    r2 = _converge(ctl, trace=trace)
    hot = _timed_mix(ctl, stacked(len(ctl.alive)))
    emit("churn_swap", phase="rejoin", n=len(ctl.alive),
         rebuild_ms=round(r2.rebuild_ms, 3), compile_ms=0.0,
         exec_ms=round(hot, 2), cache_hit=int(r2.cache_hit))

    # quiescent control steps are pure cache hits
    for _ in range(5):
        ctl.step(1.0)
    emit("churn_swap_totals", rebuilds=ctl.rebuilds, swaps=ctl.swaps,
         cache_size=len(ctl.cache), cache_hits=ctl.cache.hits,
         cache_misses=ctl.cache.misses,
         hit_rate=round(ctl.cache.hit_rate, 3))


if __name__ == "__main__":
    run()
