"""Paper Fig. 11: accuracy under different non-iid levels (shards per
client 4 / 8 / 12 ⇒ more shards = closer to iid)."""

from __future__ import annotations

from repro.core.dfl import Engine

from .common import emit, mnist_task


def run(quick: bool = False) -> None:
    engine = Engine()
    shard_levels = (2, 4) if quick else (2, 4, 8)
    total = 25.0 if quick else 50.0
    for shards in shard_levels:
        task = mnist_task(n_clients=12, shards=shards)
        for method in ("fedlay", "fedavg", "gaia"):
            res = engine.run(task, method, total_time=total,
                             model_bytes=4096, seed=0)
            tr = res.trace
            emit("fig11", shards_per_client=shards, method=method,
                 acc=round(res.final_mean_acc, 4),
                 acc_spread=round(tr[-1].max_acc - tr[-1].min_acc, 4),
                 halfway_acc=round(tr[len(tr) // 2].mean_acc, 4))


if __name__ == "__main__":
    run()
