"""ISSUE 5 microbenchmark: flat-buffer fused mixing vs the tree walk.

Five sections, one per acceptance claim:

* ``mix_fusion_parity`` — the fused global mixer ≡ the dense
  ``masked_mixing_matrix`` / ``schedule_mixing_matrix`` oracle for
  G ∈ {1, 2, 4}, masked and unmasked (max |err| ≤ 1e-6);
* ``mix_fusion_temps`` — jaxpr accounting on a full-model-sized leaf:
  the tree walk materializes 6L+1 full-model temporaries per round
  (O(2L): take/mul/add per slot), the fused path a constant ~2
  (ravel + one Pallas round kernel) at every L, with peak
  simultaneously-live full-model intermediates 2 vs 1;
* ``mix_fusion_round`` — the deployment-shaped comparison, measured in
  a subprocess on the forced 8-device host mesh (the
  ``sync_collectives`` probe idiom): one shard_map FedLay round over a
  T-leaf model.  The tree walk issues T·2L collective-permutes per
  round, the fused path exactly 2L (one flat row per slot) at
  identical wire bytes — and the per-round wall time follows
  (interleaved medians, ``speedup = tree_ms / flat_ms``);
* ``mix_fusion_memory`` — XLA ``memory_analysis`` temp bytes for the
  two compiled global programs, when the backend reports it;
* ``mix_fusion_codec`` (also runnable alone via ``--codec``) — the wire
  axis: one shard_map FedLay round per :mod:`repro.wire.codec` codec,
  HLO-measured collective-permute bytes per device next to the codec's
  ``wire_bytes`` closed form, per-round wall time, and the reduction
  factors vs the uncompressed ``fuse="flat"`` round (``wire_reduction``
  counts everything on the wire including per-block scales;
  ``payload_reduction`` the value payload alone).

Caveat for reading the timing on CPU: XLA already loop-fuses the
*global-view* tree walk into near-optimal single-pass code on one
device, so the fused path's win there is program structure, not CPU
milliseconds; the wall-clock win shows on the collective-bound
shard_map round (and, on real TPUs, in the kernel's (K+1)·N HBM
traffic).  Quick mode keeps every section seconds-fast.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

from .common import emit

_ROUND_PROBE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys, time
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.mixing import build_permute_schedule
    from repro.dist.compat import make_client_mesh, shard_map
    from repro.dist.sync import make_mixer
    from repro.launch.hlo_stats import collective_stats

    cfg = json.loads(sys.argv[1])
    L, T, leaf, reps = cfg["spaces"], cfg["leaves"], cfg["leaf"], cfg["reps"]
    n = 8
    mesh = make_client_mesh(n, "data")
    shard = NamedSharding(mesh, P("data"))
    sched = build_permute_schedule(n, L, salt="mix_fusion")
    rng = np.random.default_rng(0)
    tree = {f"l{i}": jax.device_put(
        jnp.asarray(rng.normal(size=(n, leaf)).astype(np.float32)), shard)
        for i in range(T)}
    W = jax.device_put(jnp.asarray(sched.weights), shard)
    S = jax.device_put(jnp.asarray(sched.self_weight), shard)
    specs = jax.tree.map(lambda _: P("data"), tree)

    progs, rows = {}, []
    for name, fuse in (("tree", None), ("flat", "flat")):
        mixer = make_mixer("fedlay", sched, "data", n, fuse=fuse)
        f = jax.jit(shard_map(
            lambda t, w, s, mixer=mixer: mixer(t, w, s), mesh=mesh,
            in_specs=(specs, P("data"), P("data")), out_specs=specs,
            check_vma=False))
        st = collective_stats(f.lower(tree, W, S).compile().as_text())
        rows.append({"path": name,
                     "ppermutes": st.counts.get("collective-permute", 0),
                     "wire_mb_per_dev": round(
                         st.wire_bytes_per_device / 1e6, 4)})
        progs[name] = f
    ts = {k: [] for k in progs}
    for f in progs.values():
        jax.block_until_ready(f(tree, W, S))
    for _ in range(reps):                   # interleaved: shared drift
        for k, f in progs.items():
            t0 = time.perf_counter()
            jax.block_until_ready(f(tree, W, S))
            ts[k].append(time.perf_counter() - t0)
    for row in rows:
        row["per_round_ms"] = round(
            float(np.median(ts[row["path"]])) * 1e3, 3)
    print(json.dumps(rows))
""")


_CODEC_PROBE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys, time
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.mixing import build_permute_schedule
    from repro.dist.compat import make_client_mesh, shard_map
    from repro.dist.flat import FlatSpec
    from repro.dist.sync import make_mixer
    from repro.launch.hlo_stats import collective_stats
    from repro.wire.codec import get_codec

    cfg = json.loads(sys.argv[1])
    L, T, leaf, reps = cfg["spaces"], cfg["leaves"], cfg["leaf"], cfg["reps"]
    n = 8
    mesh = make_client_mesh(n, "data")
    shard = NamedSharding(mesh, P("data"))
    sched = build_permute_schedule(n, L, salt="mix_fusion")
    rng = np.random.default_rng(0)
    tree = {f"l{i}": jax.device_put(
        jnp.asarray(rng.normal(size=(n, leaf)).astype(np.float32)), shard)
        for i in range(T)}
    W = jax.device_put(jnp.asarray(sched.weights), shard)
    S = jax.device_put(jnp.asarray(sched.self_weight), shard)
    specs = jax.tree.map(lambda _: P("data"), tree)
    nflat = FlatSpec.for_tree(tree).size
    res0 = jax.device_put(jnp.zeros((n, nflat), jnp.float32),
                          NamedSharding(mesh, P("data", None)))

    rows, progs, efs = [], {}, {}
    for name in cfg["codecs"]:
        codec = get_codec(name)
        ef = codec is not None and codec.error_feedback
        mixer = make_mixer("fedlay", sched, "data", n, fuse="flat",
                           codec=name)
        if ef:
            f = jax.jit(shard_map(
                lambda t, w, s, r, mixer=mixer: mixer(t, w, s, r),
                mesh=mesh,
                in_specs=(specs, P("data"), P("data"), P("data", None)),
                out_specs=(specs, P("data", None)), check_vma=False))
            hlo = f.lower(tree, W, S, res0).compile().as_text()
        else:
            f = jax.jit(shard_map(
                lambda t, w, s, mixer=mixer: mixer(t, w, s), mesh=mesh,
                in_specs=(specs, P("data"), P("data")), out_specs=specs,
                check_vma=False))
            hlo = f.lower(tree, W, S).compile().as_text()
        st = collective_stats(hlo)
        cname = name if name is not None else "uncompressed"
        wire = (codec or get_codec("none"))
        rows.append({
            "codec": cname,
            "ppermutes": st.counts.get("collective-permute", 0),
            "wire_mb": round(st.wire_bytes_per_device / 1e6, 4),
            "predicted_wire_mb": round(
                2 * L * wire.wire_bytes(nflat) / 1e6, 4),
            "payload_mb": round(
                2 * L * wire.payload_bytes(nflat) / 1e6, 4)})
        progs[cname], efs[cname] = f, ef

    ts = {k: [] for k in progs}
    call = lambda k: (progs[k](tree, W, S, res0) if efs[k]
                      else progs[k](tree, W, S))
    for k in progs:
        jax.block_until_ready(call(k))
    for _ in range(reps):                   # interleaved: shared drift
        for k in progs:
            t0 = time.perf_counter()
            jax.block_until_ready(call(k))
            ts[k].append(time.perf_counter() - t0)
    for row in rows:
        row["per_round_ms"] = round(
            float(np.median(ts[row["codec"]])) * 1e3, 3)
    print(json.dumps(rows))
""")


def _var_nbytes(v) -> int:
    aval = getattr(v, "aval", None)
    if aval is None or not hasattr(aval, "shape"):
        return 0
    n = 1
    for d in aval.shape:
        n *= int(d)
    return n * aval.dtype.itemsize


def full_model_temp_stats(fn, args, model_bytes: int, thresh: float = 0.9):
    """(count, peak_live, total_eqns) of full-model-sized intermediates
    in ``fn``'s jaxpr: ``count`` is how many eqn outputs of ≥
    ``thresh·model_bytes`` the round materializes (the HBM-traffic
    proxy: each is one full-model write + later read), ``peak_live``
    how many coexist at the worst program point (the memory proxy).
    The Pallas round kernel is one opaque eqn — its VMEM tiles are not
    HBM temporaries and are not counted."""
    import jax
    jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
    lim = thresh * model_bytes
    last_use = {}
    for idx, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if hasattr(v, "count"):
                last_use[v] = idx
    for v in jaxpr.outvars:
        if hasattr(v, "count"):
            last_use[v] = len(jaxpr.eqns)
    count, peak, births = 0, 0, {}
    for idx, eqn in enumerate(jaxpr.eqns):
        for v in eqn.outvars:
            if _var_nbytes(v) >= lim:
                count += 1
                births[v] = idx
        live = sum(1 for v in births if last_use.get(v, -1) > idx)
        peak = max(peak, live)
    return count, peak, len(jaxpr.eqns)


def _parity_section(quick: bool) -> None:
    import jax, jax.numpy as jnp
    from repro.core.mixing import (build_permute_schedule,
                                   masked_mixing_matrix,
                                   schedule_mixing_matrix)
    from repro.dist.sync import global_mixer
    dim = 257 if quick else 4099            # deliberately lane-unaligned
    for G in (1, 2, 4):
        n = 8 * G
        sched = build_permute_schedule(n, 2, salt=f"parity{G}")
        rng = np.random.default_rng(G)
        X = {"a": jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32)),
             "b": jnp.asarray(rng.normal(size=(n, 3, 5)).astype(np.float32))}
        rows = np.concatenate([np.asarray(X["a"]),
                               np.asarray(X["b"]).reshape(n, -1)], axis=1)
        for masked in (False, True):
            if masked:
                mask = (rng.random(n) > 0.4).astype(np.float32)
                mask[0] = 0.0
                ref = masked_mixing_matrix(sched, mask) @ rows
                mix = jax.jit(global_mixer("fedlay", sched, masked=True,
                                           fuse="flat"))
                out = mix(X, jnp.asarray(mask))
            else:
                ref = schedule_mixing_matrix(sched) @ rows
                out = jax.jit(global_mixer("fedlay", sched,
                                           fuse="flat"))(X)
            got = np.concatenate([np.asarray(out["a"]),
                                  np.asarray(out["b"]).reshape(n, -1)],
                                 axis=1)
            emit("mix_fusion_parity", G=G, masked=int(masked),
                 max_abs_err=float(np.abs(got - ref).max()))


def _temps_section(quick: bool) -> None:
    import jax.numpy as jnp
    from repro.core.mixing import build_permute_schedule
    from repro.dist.sync import global_mixer
    C, N = 8, 16384 if quick else 262144
    x = {"w": jnp.zeros((C, N), jnp.float32)}
    model_bytes = C * N * 4
    for L in (1, 2, 3):
        sched = build_permute_schedule(C, L, salt=f"temps{L}")
        for path, fuse in (("tree", None), ("flat", "flat")):
            mix = global_mixer("fedlay", sched, fuse=fuse)
            count, peak, eqns = full_model_temp_stats(mix, (x,),
                                                      model_bytes)
            emit("mix_fusion_temps", path=path, spaces=L, slots=2 * L,
                 full_model_temps=count, peak_live=peak, eqns=eqns)


def _round_section(quick: bool) -> None:
    cfg = {"spaces": 3, "leaves": 24 if quick else 64,
           "leaf": 512 if quick else 4096, "reps": 8 if quick else 20}
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)              # the probe forces its own
    repo = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    src = os.path.join(repo, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    res = subprocess.run(
        [sys.executable, "-c", _ROUND_PROBE, json.dumps(cfg)],
        env=env, capture_output=True, text=True, timeout=600)
    if res.returncode != 0:
        raise RuntimeError(f"round probe failed:\n{res.stderr[-2000:]}")
    rows = json.loads(res.stdout.strip().splitlines()[-1])
    by_path = {r["path"]: r for r in rows}
    speedup = (by_path["tree"]["per_round_ms"]
               / by_path["flat"]["per_round_ms"])
    for r in rows:
        emit("mix_fusion_round", spaces=cfg["spaces"],
             leaves=cfg["leaves"], leaf_dim=cfg["leaf"], **{
                 k: v for k, v in r.items() if k != "path"},
             path=r["path"], speedup=round(speedup, 2))


def _memory_section(quick: bool) -> None:
    import jax, jax.numpy as jnp
    from repro.core.mixing import build_permute_schedule
    from repro.dist.sync import global_mixer
    C, N = 8, 16384 if quick else 262144
    x = {"w": jnp.zeros((C, N), jnp.float32)}
    sched = build_permute_schedule(C, 3, salt="mem")
    for path, fuse in (("tree", None), ("flat", "flat")):
        mix = jax.jit(global_mixer("fedlay", sched, fuse=fuse))
        temp = -1
        try:
            mem = mix.lower(x).compile().memory_analysis()
            temp = int(getattr(mem, "temp_size_in_bytes", -1))
        except Exception:                    # backend doesn't report it
            pass
        emit("mix_fusion_memory", path=path, model_mb=round(
            C * N * 4 / 1e6, 3), temp_mb=round(temp / 1e6, 3)
            if temp >= 0 else -1)


def _codec_section(quick: bool) -> None:
    cfg = {"spaces": 3, "leaves": 12 if quick else 48,
           "leaf": 512 if quick else 4096, "reps": 5 if quick else 15,
           "codecs": [None, "bf16", "int8-block", "int4-block", "topk"]}
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)              # the probe forces its own
    repo = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    src = os.path.join(repo, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    res = subprocess.run(
        [sys.executable, "-c", _CODEC_PROBE, json.dumps(cfg)],
        env=env, capture_output=True, text=True, timeout=600)
    if res.returncode != 0:
        raise RuntimeError(f"codec probe failed:\n{res.stderr[-2000:]}")
    rows = json.loads(res.stdout.strip().splitlines()[-1])
    base = next(r for r in rows if r["codec"] == "uncompressed")
    for r in rows:
        emit("mix_fusion_codec", spaces=cfg["spaces"],
             leaves=cfg["leaves"], leaf_dim=cfg["leaf"],
             codec=r["codec"], ppermutes=r["ppermutes"],
             wire_mb=r["wire_mb"],
             predicted_wire_mb=r["predicted_wire_mb"],
             per_round_ms=r["per_round_ms"],
             wire_reduction=round(
                 base["wire_mb"] / r["wire_mb"], 2)
             if r["wire_mb"] > 0 else -1,
             payload_reduction=round(
                 base["payload_mb"] / r["payload_mb"], 2)
             if r["payload_mb"] > 0 else -1)


def run(quick: bool = False) -> None:
    t0 = time.time()
    _parity_section(quick)
    _temps_section(quick)
    _round_section(quick)
    _memory_section(quick)
    _codec_section(quick)
    emit("mix_fusion_done", seconds=round(time.time() - t0, 1))


if __name__ == "__main__":
    if "--codec" in sys.argv:
        _codec_section(quick="--quick" in sys.argv)
    else:
        run(quick="--quick" in sys.argv)
