"""Paper Fig. 12: asynchronous (per-client periods) vs synchronous
(slowest-client paced) FedLay."""

from __future__ import annotations

from repro.core.dfl import Engine

from .common import emit, mnist_task


def run(quick: bool = False) -> None:
    engine = Engine()
    total = 25.0 if quick else 50.0
    task = mnist_task()
    for method, label in (("fedlay", "async"), ("fedlay-sync", "sync")):
        res = engine.run(task, method, total_time=total, model_bytes=4096,
                         seed=0)
        emit("fig12", mode=label, acc=round(res.final_mean_acc, 4),
             local_steps=round(res.local_steps_per_client, 1),
             msgs=round(res.messages_per_client, 1))


if __name__ == "__main__":
    run()
