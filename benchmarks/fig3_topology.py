"""Paper Fig. 3: convergence factor, diameter, average shortest path for
FedLay vs Best-of-100-RRG vs Chord/Viceroy/Waxman/DT/social, n=300."""

from __future__ import annotations

from repro.core.baselines import TOPOLOGY_REGISTRY, best_of_rrgs
from repro.core.metrics import evaluate_topology

from .common import emit


def run(n: int = 300, quick: bool = False) -> None:
    degrees = (4, 6, 8) if quick else (4, 6, 8, 10, 12, 14)
    trials = 20 if quick else 100
    for d in degrees:
        fed = evaluate_topology(TOPOLOGY_REGISTRY["fedlay"](n, d // 2))
        best = evaluate_topology(best_of_rrgs(n, d, trials=trials))
        for name, rep in (("fedlay", fed), ("best_rrg", best)):
            emit("fig3", topology=name, n=n, degree=d,
                 convergence_factor=round(rep.convergence_factor, 3),
                 spectral_lambda=round(rep.spectral_lambda, 4),
                 diameter=rep.diameter,
                 avg_shortest_path=round(rep.avg_shortest_path, 3))
    for name in ("chord", "viceroy", "waxman", "delaunay", "social",
                 "ring", "grid2d", "torus", "hypercube"):
        rep = evaluate_topology(TOPOLOGY_REGISTRY[name](n))
        emit("fig3", topology=name, n=n, degree=round(rep.avg_degree, 1),
             convergence_factor=round(rep.convergence_factor, 3),
             spectral_lambda=round(rep.spectral_lambda, 4),
             diameter=rep.diameter,
             avg_shortest_path=round(rep.avg_shortest_path, 3))


if __name__ == "__main__":
    run()
