"""Paper Figs. 13/14 (§IV-C): biased-locality data — 10 groups, each
holding 6 of 10 labels shifted by one per group.  FedLay vs Chord vs the
complete-graph upper bound, across degrees."""

from __future__ import annotations

from repro.core.baselines import TOPOLOGY_REGISTRY
from repro.core.dfl import Engine, MethodSpec, capacity_periods
from repro.data.noniid import biased_locality_partition
from repro.data.synthetic import mnist_like
from repro.models.small import MLPTask

from .common import emit


def run(quick: bool = False) -> None:
    n = 10 if quick else 20
    total = 25.0 if quick else 50.0
    data = mnist_like(n_train=1500, n_test=400, seed=0)
    part = biased_locality_partition(data.y_train, n, num_groups=10,
                                     labels_per_group=6,
                                     samples_per_label=25)
    task = MLPTask(data, part, hidden=32, local_steps=2, batch=32)
    periods = capacity_periods(n, 1.0, seed=0)

    engine = Engine()
    degrees = (4, 6) if quick else (4, 6, 10)
    for d in degrees:
        # FedLay at explicit degree 2L: an ad-hoc spec overriding the
        # registered topology factory's num_spaces
        spec = MethodSpec(name=f"fedlay-d{d}",
                          topology=TOPOLOGY_REGISTRY["fedlay"](n, d // 2))
        res = engine.run(task, spec, total_time=total, model_bytes=4096,
                         periods=periods, seed=0)
        emit("fig13", topology="fedlay", degree=d,
             acc=round(res.final_mean_acc, 4))
    for name in ("chord", "complete"):
        topo = TOPOLOGY_REGISTRY[name](n)
        res = engine.run(task, name, total_time=total, model_bytes=4096,
                         periods=periods, seed=0)
        emit("fig13", topology=name,
             degree=round(sum(topo.degrees().values()) / n, 1),
             acc=round(res.final_mean_acc, 4))


if __name__ == "__main__":
    run()
