"""Beyond-paper serving benchmark: sustained request throughput under a
Poisson arrival trace — continuous batching vs the static-batch
baseline.

The paper's north star is models that survive churn *and then serve
heavy traffic*; this benchmark measures the serving analogue of the
training runtime's churn story.  One
:class:`repro.runtime.serving.ServeLoop` per admission policy replays
the identical Poisson trace (same arrivals, same prompts, same
generation lengths):

* ``continuous`` — a request joins any free slot mid-flight (prompt
  arrival = join, completion = leave; in-place row writes on the
  per-slot position vector);
* ``static`` — the classic baseline: admit only into an empty batch,
  then drain it completely, so short generations idle their slots
  while the longest one finishes.

Tables:

* ``serve_parity`` — the decode stack's correctness gate: per-slot-pos
  ``flash_decode`` ≡ the pure-jnp ``cache_attention`` oracle within
  1e-5 (mixed live/empty slots, odd cache length).
* ``serve_load`` — per policy: requests/s, tokens/s, p50/p99 request
  latency (from obs-ledger-stamped request records), decode retraces
  after warmup (must be 0 across churn), and distinct batch
  occupancies observed (≥ 3 proves real churn).  A final ``speedup``
  row gates continuous ≥ static throughput.
"""

from __future__ import annotations

import time

import numpy as np

from .common import emit

_CLOCK = time.perf_counter


def _parity_rows() -> None:
    import jax.numpy as jnp
    from repro.kernels.flash_decode import flash_decode
    from repro.models.attention import cache_attention

    rng = np.random.default_rng(0)
    B, Hq, Hkv, hd, L = 4, 8, 2, 32, 160      # odd L: lane-alignment path
    q = jnp.asarray(rng.standard_normal((B, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, L, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, L, Hkv, hd)), jnp.float32)
    pos = jnp.asarray([5, -1, L - 1, 0], jnp.int32)   # live/empty/full/fresh
    out = flash_decode(q, k, v, pos, interpret=True)
    ref = cache_attention(q[:, None], k, v, pos)[:, 0]
    diff = float(jnp.abs(out - ref).max())
    empty = float(jnp.abs(out[1]).max())
    emit("serve_parity", case="per_slot_pos", cache_len=L,
         max_abs_diff=f"{diff:.2e}", within_1e5=int(diff <= 1e-5),
         empty_slot_zero=int(empty == 0.0))


def _make_trace(rng, n_requests: int, prompt_len: int, gen_max: int,
                rate: float):
    """(arrival_tick, prompt, max_new) triples — one Poisson process
    replayed identically by both policies."""
    gaps = rng.poisson(lam=1.0 / rate, size=n_requests)
    ticks = np.cumsum(gaps)
    return [(int(t),
             rng.integers(0, 512, int(rng.integers(1, prompt_len + 1))),
             int(rng.integers(1, gen_max + 1)))
            for t in ticks]


def _drive(loop, trace):
    """Replay the trace tick-by-tick; returns (wall_s, occupancies)."""
    i = 0
    tick = 0
    occup = set()
    t0 = _CLOCK()
    while i < len(trace) or loop.pending or loop.active:
        while i < len(trace) and trace[i][0] <= tick:
            _, prompt, max_new = trace[i]
            loop.submit(prompt, max_new=max_new, arrival_tick=tick)
            i += 1
        loop.tick()
        occup.add(len(loop.slots))
        tick += 1
    return _CLOCK() - t0, occup


def run(quick: bool = False) -> None:
    import jax
    from repro.launch.train import tiny_lm
    from repro.models import init_params
    from repro.obs.rounds import get_round_ledger
    from repro.runtime.serving import ServeLoop

    _parity_rows()

    layers, capacity, prompt_len, gen_max, n_req = \
        (2, 4, 8, 10, 16) if quick else (4, 8, 16, 24, 48)
    cfg = tiny_lm(layers=layers)
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache_len = prompt_len + gen_max

    results = {}
    for policy in ("continuous", "static"):
        rng = np.random.default_rng(7)          # identical trace per policy
        trace = _make_trace(rng, n_req, prompt_len, gen_max, rate=1.0)
        loop = ServeLoop(cfg, params, capacity=capacity, cache_len=cache_len,
                         prompt_len=prompt_len, policy=policy)
        # warmup outside the timed trace: compile prefill/insert/decode/
        # retire once so p99 is serving latency, not XLA compile time
        loop.submit(trace[0][1], max_new=2)
        loop.run()
        loop.completed.clear()
        warm_traces = loop.traces

        wall, occup = _drive(loop, trace)
        lat_ms = np.asarray([r.latency_s * 1e3 for r in loop.completed])
        toks = sum(len(r.tokens) for r in loop.completed)
        retraces = loop.traces - warm_traces
        results[policy] = len(loop.completed) / wall
        ledger = get_round_ledger()
        if ledger is not None:
            ledger.record(round=loop.tick_index, loop=f"serve[{policy}]",
                          num_alive=0, retraces=retraces,
                          p50_ms=round(float(np.percentile(lat_ms, 50)), 3),
                          p99_ms=round(float(np.percentile(lat_ms, 99)), 3),
                          requests=len(loop.completed))
        emit("serve_load", policy=policy, capacity=capacity,
             requests=len(loop.completed), tokens=toks,
             requests_per_s=round(len(loop.completed) / wall, 2),
             tok_per_s=round(toks / wall, 1),
             p50_ms=round(float(np.percentile(lat_ms, 50)), 2),
             p99_ms=round(float(np.percentile(lat_ms, 99)), 2),
             retraces=retraces,
             distinct_occupancies=len(occup))

    emit("serve_load", policy="continuous_vs_static",
         speedup=round(results["continuous"] / results["static"], 3),
         continuous_wins=int(results["continuous"] >= results["static"]))


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(quick=not args.full)
