"""Fault-storm benchmark: convergence under loss × partition × straggler.

The robustness gate for :mod:`repro.faults`: the same consensus
workload (identity local step, slot loop mixing over the live FedLay
overlay) runs fault-free and under seeded :class:`~repro.faults.FaultPlan`
storms, and we measure **rounds-to-target** — how many mixing rounds
until the alive population's parameters agree within a tolerance.
Degraded rounds renormalize away unreachable edges (stragglers, link
outages, partitions), so the storm arms converge slower but must stay
within ``ROUNDS_RATIO_BOUND ×`` the clean arm — the committed bound CI
asserts on (``ratio_ok``).  A partitioned overlay cannot reach global
consensus at all until it heals, which is exactly what the
partition arm's window exercises.

Also measured: **repair latency** — simulated seconds from the
partition-heal event until NDMP correctness returns to 1.0 (the chaos
engine's rejoin sweep + Theorem-1 splices), and the loop's retrace
count (fault storms are runtime-input-only: 0 retraces after the
first trace).

Axes swept: message-loss rate {0, 10%}, one 2-way partition-and-heal,
2 stragglers.  ``--quick`` shrinks population and horizon for the CI
smoke job.
"""

from __future__ import annotations

import numpy as np

from repro.core.ndmp import Simulator
from repro.faults import ChaosEngine, FaultPlan, Partition, Straggler
from repro.optim.optimizers import sgd
from repro.overlay import OverlayController
from repro.runtime import SlotTrainLoop, masked_local_step

from .common import emit

#: CI gate: storm arms must converge within this factor of the clean arm.
ROUNDS_RATIO_BOUND = 3.0

#: Consensus tolerance: max |w - mean(w)| over alive rows.
TARGET_SPREAD = 1e-3


def _make_sim(n: int, seed: int = 0) -> Simulator:
    sim = Simulator(num_spaces=2, latency=0.05, heartbeat_period=0.5,
                    probe_period=1.0, seed=seed)
    sim.seed_network(list(range(n)))
    return sim


#: Fault windows in simulated seconds (= rounds at step_time 1.0) —
#: they open at round 2, squarely inside the convergence window, so
#: every storm arm actually converges *through* its faults.
PARTITION_WINDOW = (2.0, 14.0)
STRAGGLE_WINDOW = (2.0, 18.0)


def _storm_plan(n: int, loss: float, partition: bool,
                stragglers: int) -> FaultPlan:
    """The seeded storm: ``loss`` NDMP message loss for the whole run,
    one 2-way partition-and-heal, and ``stragglers`` slow nodes."""
    parts = ()
    if partition:
        half = tuple(range(n // 2)), tuple(range(n // 2, n))
        parts = (Partition(start=PARTITION_WINDOW[0],
                           end=PARTITION_WINDOW[1], groups=half),)
    slow = tuple(Straggler(start=STRAGGLE_WINDOW[0],
                           end=STRAGGLE_WINDOW[1],
                           node=n - 1 - i) for i in range(stragglers))
    return FaultPlan(seed=7, msg_loss=loss, partitions=parts,
                     stragglers=slow)


def _consensus_loop(sim, capacity: int, dim: int) -> SlotTrainLoop:
    """Identity local step: only mixing moves the parameters, so
    rounds-to-consensus isolates the overlay's (possibly degraded)
    mixing quality."""

    def make_params(u):
        w = np.random.default_rng(u).normal(size=dim).astype(np.float32)
        return {"w": w}

    def make_batch(node_ids, step):
        return {"x": np.zeros((len(node_ids), 1), np.float32)}

    def base_step(params, opt_state, batch):
        import jax.numpy as jnp
        loss = jnp.mean(params["w"] ** 2, axis=-1)
        return params, opt_state, {"loss": loss}

    return SlotTrainLoop(
        OverlayController(sim, capacity=capacity),
        local_step=masked_local_step(base_step),
        make_params=make_params, optimizer=sgd(0.0),
        make_batch=make_batch, step_time=1.0)


def _rounds_to_consensus(loop: SlotTrainLoop, max_rounds: int,
                         target: float = TARGET_SPREAD):
    """(rounds, reached): rounds of run(1) until every alive row is
    within ``target`` of the alive mean."""
    ctl = loop.controller
    for r in range(max_rounds):
        loop.run(1)
        slots = [ctl.slots.slot_of[u] for u in ctl.alive]
        rows = np.asarray(loop.params["w"])[slots]
        spread = float(np.abs(rows - rows.mean(axis=0)).max())
        if spread < target:
            return r + 1, True
    return max_rounds, False


def _repair_latency(n: int, plan: FaultPlan, heal_t: float,
                    timeout: float = 120.0) -> float:
    """Simulated seconds from the partition heal until NDMP correctness
    returns to 1.0 on the object engine (the rejoin-sweep repair
    latency the paper's 3T detection + Theorem-1 splicing predicts is
    short)."""
    sim = ChaosEngine(_make_sim(n, seed=1), plan)
    sim.run_until(heal_t)
    t = heal_t
    while sim.correctness() < 1.0 and t - heal_t < timeout:
        t += 0.5
        sim.run_until(t)
    return t - heal_t


def run(quick: bool = False) -> None:
    n = 8 if quick else 16
    capacity = 8 if quick else 16
    dim = 64 if quick else 512
    max_rounds = 120 if quick else 400

    # --- clean arm: the baseline rounds-to-target ------------------------
    clean = _consensus_loop(_make_sim(n), capacity, dim)
    clean_rounds, clean_ok = _rounds_to_consensus(clean, max_rounds)
    emit("fault_storm", arm="clean", loss_rate=0.0, partition=0,
         stragglers=0, n=n, rounds_to_target=clean_rounds,
         reached=int(clean_ok), retraces=clean.trace_count.retraces,
         rounds_ratio=1.0, ratio_ok=1)

    # --- storm arms ------------------------------------------------------
    # The ratio gate only makes sense for faults that *don't* freeze
    # part of the population: a straggler's (or partitioned node's)
    # parameters cannot move while its window is open, so those arms
    # are gated on recovery — consensus within ``bound × clean`` rounds
    # of the fault window closing — instead of on the raw ratio.
    arms = [
        ("loss", 0.10, False, 0, 0.0),
        ("loss+straggle", 0.10, False, 2, STRAGGLE_WINDOW[1]),
        ("loss+partition+straggle", 0.10, True, 2,
         max(STRAGGLE_WINDOW[1], PARTITION_WINDOW[1])),
    ]
    all_ok = bool(clean_ok)
    worst_ratio = 1.0
    for name, loss, part, slow, fault_end in arms:
        plan = _storm_plan(n, loss, part, slow)
        sim = ChaosEngine(_make_sim(n), plan)
        loop = _consensus_loop(sim, capacity, dim)
        rounds, ok = _rounds_to_consensus(loop, max_rounds)
        budget = ROUNDS_RATIO_BOUND * clean_rounds
        if fault_end:  # recovery gate: rounds past the window closing
            recovery = rounds - fault_end
            arm_ok = ok and recovery <= budget
            extra = {"fault_end_round": int(fault_end),
                     "recovery_rounds": round(recovery, 1)}
        else:  # pure message loss: straight ratio gate vs clean
            ratio = rounds / max(clean_rounds, 1)
            worst_ratio = max(worst_ratio, ratio)
            arm_ok = ok and ratio <= ROUNDS_RATIO_BOUND
            extra = {"rounds_ratio": round(ratio, 2)}
        all_ok = all_ok and arm_ok
        emit("fault_storm", arm=name, loss_rate=loss, partition=int(part),
             stragglers=slow, n=n, rounds_to_target=rounds,
             reached=int(ok), retraces=loop.trace_count.retraces,
             faults_injected=sum(sim.counts.values()),
             ratio_ok=int(arm_ok), **extra)

    # --- repair latency after partition heal -----------------------------
    plan = _storm_plan(n, 0.10, True, 0)
    latency = _repair_latency(n, plan, heal_t=PARTITION_WINDOW[1])
    repair_ok = latency < 60.0
    emit("fault_storm_repair", n=n, loss_rate=0.10,
         repair_latency_s=round(latency, 2), repair_ok=int(repair_ok))

    emit("fault_storm_gate", n=n, worst_rounds_ratio=round(worst_ratio, 2),
         bound=ROUNDS_RATIO_BOUND,
         gate_ok=int(all_ok and repair_ok
                     and worst_ratio <= ROUNDS_RATIO_BOUND))


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
