"""Beyond-paper §Perf: cross-pod ICI traffic of model-sync strategies on
the 2-pod production mesh (32 data-parallel clients = 2 pods × 16).

Compares, per mixing round and per cross-pod link:
  * all-reduce (centralized baseline) — every gradient chunk crosses;
  * FedLay, paper-faithful random coordinates — ≈ half of all ring
    edges cross pods;
  * FedLay + pod-biased coordinates (ours) — exactly P crossings per
    ring space;
and the spectral price (λ / convergence factor) of the bias.
"""

from __future__ import annotations

import numpy as np

from repro.core.metrics import evaluate_topology
from repro.core.mixing import (build_permute_schedule, cross_pod_messages,
                               schedule_mixing_matrix)
from repro.core.topology import Topology

from .common import emit


def _topology_of(sched) -> Topology:
    n = sched.num_clients
    edges = set()
    for k in range(sched.num_slots):
        for dst, src in enumerate(sched.perms[k]):
            if src != dst:
                edges.add((min(src, dst), max(src, dst)))
    return Topology(nodes=tuple(range(n)), edges=frozenset(edges))


def run(quick: bool = False) -> None:
    n, L, pods = 32, 3, 2
    model_mb = 8.0  # qwen3-4b bf16 grads ≈ 8 GB/1000 → per-client share
    for label, kwargs in (("fedlay_random", {}),
                          ("fedlay_podbias", {"pod_bias": pods}),
                          ("fedlay_podbias_2of3",
                           {"pod_bias": pods, "pod_bias_spaces": 2}),
                          ("fedlay_podbias_1of3",
                           {"pod_bias": pods, "pod_bias_spaces": 1})):
        sched = build_permute_schedule(n, L, **kwargs)
        crossing = cross_pod_messages(sched, pods)
        total_msgs = sched.num_slots * n
        rep = evaluate_topology(_topology_of(sched))
        emit("crosspod", strategy=label, clients=n, pods=pods,
             crossing_msgs_per_round=crossing,
             total_msgs_per_round=total_msgs,
             crossing_fraction=round(crossing / total_msgs, 3),
             crosspod_mb_per_round=round(crossing * model_mb, 1),
             spectral_lambda=round(rep.spectral_lambda, 4),
             convergence_factor=round(rep.convergence_factor, 2))
    # all-reduce over the joint (pod,data) axis: ring algorithm — the
    # pod-cut is traversed by ~2/n of each of the 2(n-1) chunk hops per
    # client, i.e. cross-pod bytes ≈ 4·M total per round (both ring
    # directions), independent of n.
    emit("crosspod", strategy="allreduce", clients=n, pods=pods,
         crossing_msgs_per_round="2chunks*2dirs",
         total_msgs_per_round=2 * (n - 1) * n,
         crossing_fraction=round(4.0 / (2 * (n - 1)), 3),
         crosspod_mb_per_round=round(4 * model_mb, 1),
         spectral_lambda=0.0, convergence_factor=1.0)


if __name__ == "__main__":
    run()
