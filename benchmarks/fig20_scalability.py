"""Paper Fig. 20: scalability — now spanning the object simulator's
exact regime (10^2–10^3) *and* the vectorized engine's population scale
(10^5+, `repro.scale.ndmp_vec`).

Quick mode runs both engines at small n with a vec-vs-object parity row
(identical converged neighbor tables on the same churn); full mode
pushes the vectorized engine to 10^4 and 10^5 nodes — protocol build /
batched-churn throughput plus sampled-BFS topology quality (the dense
eigensolve of ``evaluate_topology`` stops at 10^3).

CLI (engine + sizes are selectable without editing the file)::

  PYTHONPATH=src python -m benchmarks.fig20_scalability \
      [--engine object|vec|both] [--sizes 100,1000,100000] [--full]

and through the harness (artifact + regression gate)::

  PYTHONPATH=src python -m benchmarks.run --only fig20 --json [--full]
"""

from __future__ import annotations

import argparse
import time
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.baselines import TOPOLOGY_REGISTRY
from repro.core.metrics import evaluate_topology
from repro.core.ndmp import Simulator
from repro.dist.sync import sync_bytes_per_client
from repro.scale import VectorSimulator

from .common import emit

MODEL_MB = 1.1  # paper's CNN model size
DENSE_METRICS_MAX = 1000      # evaluate_topology is O(n^2) memory
BFS_SOURCES = 8


# --------------------------------------------------------------------------
# Scalable topology metrics (CSR + sampled BFS, no dense n×n anything)
# --------------------------------------------------------------------------

def _vec_edges(sim: VectorSimulator) -> Tuple[np.ndarray, int]:
    """Deduped undirected edge array (E, 2) over alive positions."""
    rows, succ, _ = sim.neighbor_rows()
    n = len(rows)
    pairs = []
    idx = np.arange(n)
    for s in range(sim.num_spaces):
        ok = succ[s] >= 0
        a, b = idx[ok], succ[s][ok]
        keep = a != b
        pairs.append(np.stack([np.minimum(a[keep], b[keep]),
                               np.maximum(a[keep], b[keep])], axis=1))
    edges = np.unique(np.concatenate(pairs, axis=0), axis=0)
    return edges, n


def _csr(edges: np.ndarray, n: int) -> Tuple[np.ndarray, np.ndarray]:
    both = np.concatenate([edges, edges[:, ::-1]], axis=0)
    order = np.argsort(both[:, 0], kind="stable")
    both = both[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, both[:, 0] + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, both[:, 1].copy()

def _sampled_aspl(indptr: np.ndarray, indices: np.ndarray, n: int,
                  sources: int, seed: int = 0) -> Tuple[float, int]:
    """(avg shortest path, eccentricity max) over BFS from a source
    sample — frontier-vectorized, O(sources · (V + E))."""
    rng = np.random.default_rng(seed)
    srcs = rng.choice(n, size=min(sources, n), replace=False)
    total, count, ecc = 0.0, 0, 0
    for s in srcs:
        dist = np.full(n, -1, dtype=np.int32)
        dist[s] = 0
        frontier = np.asarray([s], dtype=np.int64)
        d = 0
        while len(frontier):
            d += 1
            # all neighbors of the frontier in one gather
            spans = [indices[indptr[u]:indptr[u + 1]] for u in frontier]
            nxt = np.unique(np.concatenate(spans)) if spans else np.empty(0)
            nxt = nxt[dist[nxt] < 0]
            if not len(nxt):
                break
            dist[nxt] = d
            frontier = nxt
        reached = dist[dist > 0]
        total += float(reached.sum())
        count += int(len(reached))
        ecc = max(ecc, int(dist.max()))
    return (total / count if count else float("nan")), ecc


# --------------------------------------------------------------------------
# Per-engine protocol benchmarks
# --------------------------------------------------------------------------

def _bench_object(n: int) -> None:
    t0 = time.perf_counter()
    sim = Simulator(num_spaces=3, latency=0.05, heartbeat_period=0.5,
                    probe_period=1.0, seed=0)
    sim.seed_network(list(range(n)))
    build_ms = (time.perf_counter() - t0) * 1e3
    k = max(1, n // 100)
    t0 = time.perf_counter()
    for f in range(k):
        sim.fail(f)
    for j in range(n + 1000, n + 1000 + k):
        sim.join(j, bootstrap=n // 2)
    sim.run_for(30.0)
    churn_s = time.perf_counter() - t0
    emit("fig20_protocol", engine="object", n=n,
         build_ms=round(build_ms, 2),
         churn_ops_per_s=round(2 * k / churn_s, 1),
         correctness=round(sim.correctness(), 4))


def _bench_vec(n: int) -> None:
    t0 = time.perf_counter()
    sim = VectorSimulator(num_spaces=3, latency=0.05, heartbeat_period=0.5,
                          probe_period=1.0)
    sim.seed_network(range(n))
    build_ms = (time.perf_counter() - t0) * 1e3
    k = max(1, n // 100)
    t0 = time.perf_counter()
    sim.fail_batch(range(k))
    sim.join_batch(range(n + 1000, n + 1000 + k))
    sim.run_for(30.0)
    churn_s = time.perf_counter() - t0
    correctness = sim.correctness() if n <= 10_000 else None
    t0 = time.perf_counter()
    edges, n_alive = _vec_edges(sim)
    indptr, indices = _csr(edges, n_alive)
    deg = np.diff(indptr)
    aspl, ecc = _sampled_aspl(indptr, indices, n_alive, BFS_SOURCES)
    metrics_ms = (time.perf_counter() - t0) * 1e3
    row = dict(engine="vec", n=n, build_ms=round(build_ms, 2),
               churn_ops_per_s=round(2 * k / churn_s, 1),
               metrics_ms=round(metrics_ms, 2),
               avg_degree=round(float(deg.mean()), 2),
               max_degree=int(deg.max()),
               sampled_aspl=round(aspl, 2), sampled_ecc=ecc)
    if correctness is not None:
        row["correctness"] = round(correctness, 4)
    emit("fig20_protocol", **row)


def _parity_row(n: int) -> None:
    """Converged-table equality of the two engines on the same churn."""
    kw = dict(num_spaces=3, latency=0.05, heartbeat_period=0.5,
              probe_period=1.0)
    obj = Simulator(seed=0, **kw)
    obj.seed_network(list(range(n)))
    vec = VectorSimulator(**kw)
    vec.seed_network(range(n))
    for f in range(0, 4):
        obj.fail(f)
        vec.fail(f)
    for j in range(n + 10, n + 14):
        obj.join(j, bootstrap=n // 2)
        vec.join(j)
    obj.run_for(30.0)
    vec.run_for(30.0)
    emit("fig20_parity", n=n,
         tables_equal=obj.neighbor_tables() == vec.neighbor_tables(),
         object_correct=round(obj.correctness(), 4),
         vec_correct=round(vec.correctness(), 4))


def run(quick: bool = False, engine: Optional[str] = None,
        sizes: Optional[Sequence[int]] = None) -> None:
    engine = engine or ("both" if quick else "vec")
    if sizes is None:
        sizes = (100, 300) if quick else (10_000, 100_000)
    for n in sizes:
        if engine in ("object", "both") and n <= 2000:
            _bench_object(n)
        if engine in ("vec", "both"):
            _bench_vec(n)
        if n <= DENSE_METRICS_MAX:
            rep = evaluate_topology(TOPOLOGY_REGISTRY["fedlay"](n, 3))
            emit("fig20_topology", n=n,
                 convergence_factor=round(rep.convergence_factor, 2),
                 diameter=rep.diameter,
                 aspl=round(rep.avg_shortest_path, 2))
        for strategy in ("fedlay", "allreduce", "ring", "complete"):
            mb = sync_bytes_per_client(strategy, int(MODEL_MB * 1e6), n, 3)
            emit("fig20_comm", n=n, strategy=strategy,
                 mbytes_per_round_per_client=round(mb / 1e6, 2))
        # cohort streaming: K of n active, induced-subgraph degree
        cohort = min(64, n)
        mb = sync_bytes_per_client("fedlay", int(MODEL_MB * 1e6), n, 3,
                                   active_clients=cohort)
        emit("fig20_comm", n=n, strategy="fedlay_cohort",
             active_clients=cohort,
             mbytes_per_round_per_client=round(mb / 1e6, 2))
    if engine == "both":
        _parity_row(min(sizes))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine", choices=("object", "vec", "both"),
                    default=None, help="NDMP engine(s) to benchmark")
    ap.add_argument("--sizes", default=None,
                    help="comma-separated network sizes")
    ap.add_argument("--full", action="store_true",
                    help="population scale (10^4, 10^5 via the "
                         "vectorized engine)")
    args = ap.parse_args()
    sizes = (tuple(int(s) for s in args.sizes.split(","))
             if args.sizes else None)
    run(quick=not args.full, engine=args.engine, sizes=sizes)


if __name__ == "__main__":
    main()
