"""Paper Fig. 20: scalability — topology quality, correctness under
construction, and per-client communication at n up to 1000 clients
(large-scale simulation mode: topology + protocol, no per-client
training, exactly like the paper's >100-client methodology)."""

from __future__ import annotations

import numpy as np

from repro.core.baselines import TOPOLOGY_REGISTRY
from repro.core.metrics import evaluate_topology
from repro.dist.sync import sync_bytes_per_client

from .common import emit


def run(quick: bool = False) -> None:
    sizes = (100, 300) if quick else (100, 200, 500, 1000)
    model_mb = 1.1  # paper's CNN model size
    for n in sizes:
        rep = evaluate_topology(TOPOLOGY_REGISTRY["fedlay"](n, 3))
        emit("fig20_topology", n=n,
             convergence_factor=round(rep.convergence_factor, 2),
             diameter=rep.diameter,
             aspl=round(rep.avg_shortest_path, 2))
        for strategy in ("fedlay", "allreduce", "ring", "complete"):
            mb = sync_bytes_per_client(strategy, int(model_mb * 1e6), n, 3)
            emit("fig20_comm", n=n, strategy=strategy,
                 mbytes_per_round_per_client=round(mb / 1e6, 2))


if __name__ == "__main__":
    run()
