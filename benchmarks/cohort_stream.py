"""Beyond-paper microbenchmark: cohort streaming over a huge overlay.

The fixed-capacity device pool (C slots) serves an overlay of n ≫ C
nodes: each round a :class:`repro.scale.cohort.CohortSampler` draws a
K-node cohort, the :class:`~repro.runtime.slots.SlotMap` streams
members in/out of the resident (C, dim) buffer, and the induced-FedLay
mixing round runs through the :func:`repro.kernels.weighted_mix.gather_mix`
traced-source path — cohort composition is pure runtime data, so every
round of every cohort reuses ONE compiled program.

Two tables:

* ``cohort_oracle`` — correctness: the device round must equal the
  dense :func:`repro.scale.cohort.cohort_mixing_matrix` oracle within
  1e-6 across >= 3 cohort compositions with 0 retraces, and the
  full-population cohort's matrix must equal the dense
  full-participation mixing matrix exactly.
* ``cohort_stream`` — cost: rounds/s and host remap time (park /
  restore / schedule rebuild) as the cohort size K sweeps, with a
  mid-run churn burst on the underlying vectorized engine; the
  ``retraces`` column must stay 0 throughout.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.mixing import schedule_mixing_matrix, schedule_from_addresses
from repro.runtime.loop import counting_jit
from repro.scale import CohortSampler, CohortStreamLoop, VectorSimulator
from repro.scale.cohort import (cohort_addresses, cohort_mixing_matrix,
                                cohort_schedule, schedule_tables)

from .common import emit

L = 3


def _make_sim(n: int) -> VectorSimulator:
    sim = VectorSimulator(num_spaces=L, latency=0.05, heartbeat_period=0.5,
                          probe_period=1.0)
    sim.seed_network(range(n))
    return sim


def _oracle_check(quick: bool) -> None:
    """Device cohort round vs the dense mixing-matrix oracle."""
    import jax.numpy as jnp
    from repro.kernels.weighted_mix import gather_mix

    n, capacity, dim = (24, 32, 192) if quick else (48, 64, 1024)
    sim = _make_sim(n)
    alive = sim.alive_ids()
    rng = np.random.default_rng(0)
    buf = rng.random((capacity, dim), dtype=np.float32)

    mix, count = counting_jit(
        lambda b, s, w: gather_mix(b, s, w))
    sampler = CohortSampler(sim, n // 2, seed=7)
    compositions = [tuple(alive), sampler.sample(0), sampler.sample(1)]

    buf_j = jnp.asarray(buf)
    for i, cohort in enumerate(compositions):
        slot_of = {int(u): j for j, u in enumerate(cohort)}
        _, padded = cohort_schedule(cohort, L, slot_of, capacity)
        srcs, weights = schedule_tables(padded)
        out = np.asarray(mix(buf_j, jnp.asarray(srcs), jnp.asarray(weights)))
        oracle = cohort_mixing_matrix(cohort, L, slot_of, capacity) \
            @ buf.astype(np.float64)
        diff = float(np.abs(out.astype(np.float64) - oracle).max())
        emit("cohort_oracle", composition=i, k=len(cohort),
             max_abs_diff=f"{diff:.2e}", within_1e6=int(diff <= 1e-6),
             retraces=count.retraces)

    # full-participation pin: the whole population as the cohort gives
    # exactly the dense full mixing matrix (plus identity dead slots)
    full = compositions[0]
    slot_of = {int(u): j for j, u in enumerate(full)}
    M = cohort_mixing_matrix(full, L, slot_of, capacity)
    dense = schedule_mixing_matrix(
        schedule_from_addresses(cohort_addresses(full, L)))
    d_full = float(np.abs(M[:n, :n] - dense).max())
    d_dead = float(np.abs(M[n:, n:] - np.eye(capacity - n)).max())
    emit("cohort_oracle", composition="full_vs_dense", k=n,
         max_abs_diff=f"{max(d_full, d_dead):.2e}",
         within_1e6=int(max(d_full, d_dead) <= 1e-6),
         retraces=count.retraces)


def _stream_bench(quick: bool) -> None:
    """rounds/s + remap cost vs cohort size K, churn burst mid-run."""
    n, capacity, dim = (2000, 32, 256) if quick else (50_000, 128, 4096)
    rounds = 8 if quick else 24

    def make_params(u: int) -> np.ndarray:
        return np.random.default_rng(u).random(dim).astype(np.float32)

    for k in (capacity // 4, capacity // 2, capacity):
        sim = _make_sim(n)
        loop = CohortStreamLoop(sim, capacity=capacity, cohort_size=k,
                                make_params=make_params, seed=3)
        t0 = time.perf_counter()
        loop.run(rounds // 2)
        # churn burst: 1% of the overlay fails, 1% new ids join
        burst = max(1, n // 100)
        sim.fail_batch(range(burst))
        sim.join_batch(range(n + 1000, n + 1000 + burst))
        sim.run_for(30.0)
        loop.run(rounds - rounds // 2)
        dt = time.perf_counter() - t0
        recs = loop.records
        emit("cohort_stream", n=n, capacity=capacity, k=k, dim=dim,
             rounds=rounds, rounds_per_s=round(rounds / dt, 1),
             remap_ms=round(float(np.mean([r.remap_ms for r in recs])), 2),
             streamed_in=sum(r.streamed_in for r in recs),
             restored=sum(r.restored for r in recs),
             donor_seeded=sum(r.donor_seeded for r in recs),
             fresh=sum(r.fresh for r in recs),
             retraces=recs[-1].retraces)


def run(quick: bool = False) -> None:
    _oracle_check(quick)
    _stream_bench(quick)


if __name__ == "__main__":
    run()
