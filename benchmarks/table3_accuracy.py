"""Paper Table III + Fig. 9/10: accuracy at convergence for FedLay vs
FedAvg (centralized upper bound) vs Gaia / Chord / DFL-DDS on the three
tasks (synthetic stand-ins; the claim validated is the *ordering* and
the FedLay-to-FedAvg gap).

The method sweep enumerates ``repro.core.dfl.METHOD_REGISTRY`` instead
of a hard-coded tuple, so newly registered methods are benchmarked for
free.  Quick mode keeps the paper's headline five; ``--full`` sweeps the
whole registry."""

from __future__ import annotations

from typing import Optional, Sequence

from repro.core.dfl import METHOD_REGISTRY, Engine

from .common import cifar_task, emit, mnist_task, shakespeare_task

#: The paper's Table III columns, swept first and used for the gap row.
PAPER_METHODS = ("fedlay", "fedavg", "gaia", "chord", "dfl-dds")


def sweep_methods(full: bool = False) -> tuple:
    """Paper columns first, then (with ``full``) every other registered
    method in name order — additions to the registry show up here
    without touching this file."""
    if not full:
        return PAPER_METHODS
    extra = tuple(m for m in sorted(METHOD_REGISTRY)
                  if m not in PAPER_METHODS)
    return PAPER_METHODS + extra


def run_task(task_name: str, task, total_time: float, seed: int = 0,
             methods: Optional[Sequence[str]] = None) -> dict:
    engine = Engine()
    out = {}
    for method in (methods if methods is not None else PAPER_METHODS):
        res = engine.run(task, method, total_time=total_time,
                         model_bytes=4 * 1024, base_period=1.0, seed=seed)
        out[method] = res
        emit("table3", task=task_name, method=method,
             acc=round(res.final_mean_acc, 4),
             min_acc=round(res.trace[-1].min_acc, 4),
             msgs_per_client=round(res.messages_per_client, 1),
             mbytes_per_client=round(res.comm_bytes_per_client / 1e6, 3),
             local_steps=round(res.local_steps_per_client, 1))
    gap = out["fedavg"].final_mean_acc - out["fedlay"].final_mean_acc
    emit("table3_gap", task=task_name, fedavg_minus_fedlay=round(gap, 4))
    return out


def run(quick: bool = False) -> None:
    methods = sweep_methods(full=not quick)
    run_task("mnist", mnist_task(), total_time=25.0 if quick else 50.0,
             methods=methods)
    if not quick:
        run_task("cifar", cifar_task(), total_time=40.0, methods=methods)
        run_task("shakespeare", shakespeare_task(), total_time=40.0,
                 methods=methods)


if __name__ == "__main__":
    run()
