"""Paper Figs. 16/17: MEP confidence-weighted aggregation vs simple
average."""

from __future__ import annotations

from repro.core.dfl import Engine

from .common import emit, mnist_task


def run(quick: bool = False) -> None:
    engine = Engine()
    total = 25.0 if quick else 50.0
    # heavier skew so the confidence weights matter (paper's setting)
    task = mnist_task(n_clients=12, shards=2)
    for method, label in (("fedlay", "confidence"),
                          ("fedlay-noconf", "simple_average")):
        res = engine.run(task, method, total_time=total, model_bytes=4096,
                         seed=0)
        emit("fig16", aggregation=label, acc=round(res.final_mean_acc, 4),
             min_acc=round(res.trace[-1].min_acc, 4))


if __name__ == "__main__":
    run()
