"""Beyond-paper microbenchmark: slot runtime vs re-stack loop under churn.

The retrace tax the fixed-capacity slot runtime removes, measured:
both loops run the same quadratic local step over the same scripted
churn trace (>= 3 distinct alive counts).  The re-stack loop
(:class:`repro.overlay.runtime.ChurnTrainLoop`) re-stacks client state
on every membership change, so its jitted local step traces once per
distinct alive count; the slot loop
(:class:`repro.runtime.SlotTrainLoop`) holds a static (capacity, ...)
shape and traces exactly once.  Also checks the two loops' per-step
losses agree to fp tolerance (the mask/pad machinery changes the
layout, not the math) and reports steps/sec.

Plus the **telemetry overhead** axis guarding the :mod:`repro.obs`
zero-cost-when-disabled contract: the same slot loop timed with
telemetry fully disabled (``obs.disabled()``) vs fully on (bus + round
ledger), best-of-N to shed scheduler noise, emitting ``overhead_pct``
and the ``overhead_ok`` (< 2%) flag CI asserts on.
"""

from __future__ import annotations

import contextlib
import time

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.ndmp import Simulator
from repro.optim.optimizers import sgd
from repro.overlay import ChurnTrace, ChurnTrainLoop, OverlayController
from repro.runtime import SlotTrainLoop, counting_jit, masked_local_step

from .common import emit

#: The obs contract: enabling telemetry may cost < this % of steps/s.
OVERHEAD_BUDGET_PCT = 2.0


def _make_sim(n: int, seed: int = 0) -> Simulator:
    sim = Simulator(num_spaces=2, latency=0.05, heartbeat_period=0.5,
                    probe_period=1.0, seed=seed)
    sim.seed_network(list(range(n)))
    return sim


def _harness(dim: int):
    """Node-identity-keyed params/batches + the per-client local step."""

    def make_params(u):
        w = np.random.default_rng(u).normal(size=dim).astype(np.float32)
        return {"w": jnp.asarray(w)}

    def make_batch(node_ids, step):
        rows = [np.random.default_rng(abs(hash((u, step))) % 2**32)
                .normal(size=dim).astype(np.float32) for u in node_ids]
        return {"x": jnp.asarray(np.stack(rows))}

    def base_step(params, opt_state, batch):
        w, x = params["w"], batch["x"]
        loss = jnp.mean((w - x) ** 2, axis=-1)        # per-client
        grad = 2.0 * (w - x) / dim
        return {"w": w - 0.05 * grad}, opt_state, {"loss": loss}

    def restack_step(params, opt_state, batch):
        p, o, m = base_step(params, opt_state, batch)
        return p, o, {"loss": jnp.mean(m["loss"])}

    return make_params, make_batch, base_step, restack_step


def _trace(n: int) -> ChurnTrace:
    """fail, fail, rejoin-sized joins: alive counts n, n-1, n-2, n-1, n
    (>= 3 distinct counts)."""
    return ChurnTrace.scripted([
        (2.5, "fail", 1), (4.5, "fail", 3),
        (6.5, "join", 10_000, 0), (8.5, "join", 10_001, 0),
    ])


def run(quick: bool = False) -> None:
    n = 6 if quick else 24
    capacity = 8 if quick else 32
    dim = 256 if quick else 65536
    steps = 12 if quick else 40
    make_params, make_batch, base_step, restack_step = _harness(dim)
    opt = sgd(0.0)  # the toy step updates in-line; opt only seeds joiners

    # --- re-stack loop: one trace per distinct alive count ---------------
    rjit, rcount = counting_jit(restack_step)
    restack = ChurnTrainLoop(
        OverlayController(_make_sim(n)), local_step=rjit,
        make_params=make_params, optimizer=opt, make_batch=make_batch,
        jit_local_step=False)
    t0 = time.perf_counter()
    recs_r = restack.run(steps, trace=_trace(n))
    dt_r = time.perf_counter() - t0
    distinct = len({r.num_alive for r in recs_r})
    emit("slot_runtime", loop="restack", capacity=0, n0=n, dim=dim,
         steps=steps, distinct_alive=distinct, traces=rcount.traces,
         retraces=rcount.retraces, steps_per_s=round(steps / dt_r, 1),
         final_loss=round(recs_r[-1].loss, 6))

    # --- slot loop: one trace ever (static capacity shapes) --------------
    sjit, scount = counting_jit(masked_local_step(base_step))
    slot = SlotTrainLoop(
        OverlayController(_make_sim(n), capacity=capacity),
        local_step=sjit, make_params=make_params, optimizer=opt,
        make_batch=make_batch, jit_local_step=False)
    t0 = time.perf_counter()
    recs_s = slot.run(steps, trace=_trace(n))
    dt_s = time.perf_counter() - t0
    emit("slot_runtime", loop="slot", capacity=capacity, n0=n, dim=dim,
         steps=steps, distinct_alive=len({r.num_alive for r in recs_s}),
         traces=scount.traces, retraces=scount.retraces,
         steps_per_s=round(steps / dt_s, 1),
         final_loss=round(recs_s[-1].loss, 6))

    # --- parity: same trace, same losses ---------------------------------
    diff = float(np.abs(np.array([r.loss for r in recs_r])
                        - np.array([r.loss for r in recs_s])).max())
    emit("slot_runtime_parity",
         alive_seq_equal=int([r.num_alive for r in recs_r]
                             == [r.num_alive for r in recs_s]),
         max_abs_loss_diff=f"{diff:.2e}",
         slot_retraces=scount.retraces,
         restack_retraces=rcount.retraces)

    # --- telemetry overhead: off vs on, same slot loop --------------------
    # The signal (tens of us/step of host-side bookkeeping) is far below
    # scheduler/frequency noise at small windows, so: a long timing
    # window per rep, arms interleaved off/on/off/on to decorrelate
    # drift, best-of-reps per arm.
    reps = 4 if quick else 6
    t_steps = max(steps * 8, 96)

    def make_slot():
        sj, sc = counting_jit(masked_local_step(base_step))
        loop = SlotTrainLoop(
            OverlayController(_make_sim(n), capacity=capacity),
            local_step=sj, make_params=make_params, optimizer=opt,
            make_batch=make_batch, jit_local_step=False)
        return loop, sc

    def arm_context(stack, telemetry_on: bool):
        if telemetry_on:
            stack.enter_context(obs.telemetry(obs.Telemetry()))
            stack.enter_context(obs.round_ledger(obs.RoundLedger()))
        else:
            stack.enter_context(obs.disabled())

    loops = {}
    for on in (False, True):                  # warmup: compile + cache
        loops[on] = make_slot()
        with contextlib.ExitStack() as stack:
            arm_context(stack, on)
            loops[on][0].run(steps)
    best = {False: float("inf"), True: float("inf")}
    for _ in range(reps):
        for on in (False, True):
            with contextlib.ExitStack() as stack:
                arm_context(stack, on)
                t0 = time.perf_counter()
                loops[on][0].run(t_steps)
                best[on] = min(best[on], time.perf_counter() - t0)
    off_sps, on_sps = t_steps / best[False], t_steps / best[True]
    on_count = loops[True][1]
    overhead_pct = max(0.0, (off_sps - on_sps) / off_sps * 100.0)
    emit("slot_runtime_overhead", n0=n, capacity=capacity, dim=dim,
         steps=t_steps, reps=reps,
         off_steps_per_s=round(off_sps, 1), on_steps_per_s=round(on_sps, 1),
         overhead_pct=round(overhead_pct, 2),
         overhead_ok=int(overhead_pct < OVERHEAD_BUDGET_PCT),
         on_retraces=on_count.retraces)


if __name__ == "__main__":
    run()
