"""Batched serving demo: greedy decode with per-layer KV / SSM caches
against a reduced variant of any assigned architecture.

  PYTHONPATH=src python examples/serve_demo.py --arch mamba2-370m
  PYTHONPATH=src python examples/serve_demo.py --arch deepseek-v3-671b
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main  # noqa: E402

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "mamba2-370m", "--batch", "2",
                     "--prompt-len", "16", "--gen", "16"]
    sys.exit(main())
