"""End-to-end DFL training on the TPU path (deliverable b: the e2e
driver).  Eight FedLay clients — one per device — train a small LM on
non-iid token shards for a few hundred steps; model sync is the paper's
2L-ppermute FedLay mixing.  Compare against centralized all-reduce:

  python examples/dfl_train.py --steps 300
  python examples/dfl_train.py --steps 300 --sync allreduce
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main  # noqa: E402

if __name__ == "__main__":
    if "--clients" not in sys.argv:
        sys.argv += ["--clients", "8"]
    if "--steps" not in sys.argv:
        sys.argv += ["--steps", "300"]
    sys.exit(train_main())
