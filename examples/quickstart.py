"""Quickstart: the FedLay overlay in 60 seconds.

Builds a FedLay overlay from virtual coordinates, scores it against the
paper's three topology metrics, runs the decentralized join/failure
protocols, and does a miniature DFL training round — all pure host-side
(no accelerator needed).

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (NodeAddress, Simulator, TOPOLOGY_REGISTRY,
                        evaluate_topology, fedlay_topology)
from repro.core.dfl import Engine
from repro.data.noniid import shard_partition
from repro.data.synthetic import mnist_like
from repro.models.small import MLPTask
from repro.obs import RoundLedger, Telemetry


def main():
    # 1. The FedLay topology: L random ring spaces -> near-random regular
    n, L = 100, 3
    addrs = [NodeAddress.create(i, num_spaces=L) for i in range(n)]
    topo = fedlay_topology(addrs)
    rep = evaluate_topology(topo)
    print(f"FedLay n={n} L={L}: degree≤{2*L}, "
          f"λ={rep.spectral_lambda:.3f}, c_G={rep.convergence_factor:.2f}, "
          f"diameter={rep.diameter}, aspl={rep.avg_shortest_path:.2f}")
    ring = evaluate_topology(TOPOLOGY_REGISTRY["ring"](n))
    print(f"ring baseline:  c_G={ring.convergence_factor:.2f} "
          f"(FedLay mixes {ring.convergence_factor/rep.convergence_factor:.0f}x faster)")

    # 2. Decentralized construction + churn recovery (NDMP)
    sim = Simulator(num_spaces=L, latency=0.35)
    sim.seed_network(list(range(50)))
    for j in range(50, 60):
        sim.join(j, bootstrap=j % 50)
    sim.run_for(10.0)
    print(f"after 10 concurrent joins: correctness={sim.correctness():.3f}")
    for f in range(5):
        sim.fail(f)
    sim.run_for(20.0)
    print(f"after 5 abrupt failures:   correctness={sim.correctness():.3f}")

    # 3. A miniature DFL run (MEP confidence weighting, async periods),
    #    observed live through the repro.obs telemetry plane
    data = mnist_like(n_train=800, n_test=300)
    part = shard_partition(data.y_train, num_clients=10, shards_per_client=3)
    task = MLPTask(data, part, hidden=32, local_steps=2)
    bus = Telemetry()
    ledger = RoundLedger(bus=bus)
    res = Engine().run(task, "fedlay", total_time=20.0, model_bytes=4096,
                       telemetry=bus, ledger=ledger)
    print(f"DFL on non-iid shards: acc {res.trace[0].mean_acc:.2f} -> "
          f"{res.final_mean_acc:.2f} "
          f"({res.messages_per_client:.0f} msgs/client, "
          f"{res.suppressed_sends} duplicate sends suppressed)")
    print()
    print("per-round ledger (repro.obs):")
    print(ledger.summary_table())


if __name__ == "__main__":
    main()
