"""Churn resilience demo (paper Fig. 8): watch topology correctness
recover in real time as 25% of a 200-node FedLay network fails at once,
then 50 new nodes mass-join.

  PYTHONPATH=src python examples/churn_demo.py
"""

from repro.core import Simulator


def bar(x: float, width: int = 40) -> str:
    full = int(x * width)
    return "#" * full + "." * (width - full)


def main():
    sim = Simulator(num_spaces=3, latency=0.35, heartbeat_period=1.0,
                    probe_period=2.0)
    sim.seed_network(list(range(200)))
    print(f"t={sim.now:6.1f}s  correct {bar(sim.correctness())} "
          f"{sim.correctness():.3f}  (200 nodes seeded)")

    print("\n-- 50 nodes fail simultaneously --")
    for f in range(50):
        sim.fail(f)
    for _ in range(12):
        sim.run_for(1.0)
        c = sim.correctness()
        print(f"t={sim.now:6.1f}s  correct {bar(c)} {c:.3f}")
        if c == 1.0:
            break

    print("\n-- 50 new nodes join simultaneously --")
    alive = [a.node_id for a in sim.alive_addresses()]
    for j in range(1000, 1050):
        sim.join(j, bootstrap=alive[j % len(alive)])
    for _ in range(12):
        sim.run_for(1.0)
        c = sim.correctness()
        print(f"t={sim.now:6.1f}s  correct {bar(c)} {c:.3f}")
        if c == 1.0:
            break

    print(f"\nmessages/node total: {sim.avg_messages_per_node():.1f}; "
          f"network size {len(sim.alive_addresses())}")


if __name__ == "__main__":
    main()
